"""Shared machinery for the paper-figure benchmarks.

``scheme_experiment`` reproduces the motivating experiment of Section
II-B / Figure 2 and the hybrid-scan comparison of Figure 8: a fixed
index is populated under FULL / VBP / VAP (plus the paper's
spike-free decoupled-VBP variant) while a scan workload runs, isolating
the *population scheme* from any decision logic.
"""
from __future__ import annotations

import sys
import time
from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from repro.api import Database, IndexDescriptor, Workload

DEFAULT_ROWS = 20_000
DEFAULT_PAGE = 256
TIME_PER_UNIT_MS = 1e-4


@dataclass
class SchemeResult:
    scheme: str
    latencies_ms: List[float] = field(default_factory=list)
    cumulative_ms: float = 0.0
    built_fraction: List[float] = field(default_factory=list)
    wall_s: float = 0.0

    def summary(self) -> Dict[str, float]:
        # Guard the empty case: write-only workloads record no scan
        # latencies, and np.percentile raises on an empty sample.
        lat = np.asarray(self.latencies_ms)
        has = lat.size > 0
        return {"scheme": self.scheme,
                "cumulative_ms": round(self.cumulative_ms, 2),
                "mean_ms": round(float(lat.mean()), 5) if has else 0.0,
                "p99_ms": round(float(np.percentile(lat, 99)), 5)
                          if has else 0.0,
                "final_ms": round(float(lat[-20:].mean()), 5) if has else 0.0,
                "built": round(self.built_fraction[-1], 3)
                         if self.built_fraction else 0.0,
                "wall_s": round(self.wall_s, 2)}


def scheme_experiment(scheme: str, workload: Workload, db_src,
                      key_attrs=(1,), units_per_cycle: int = 1024,
                      tuning_interval_ms: float = 50.0,
                      time_per_unit_ms: float = TIME_PER_UNIT_MS,
                      arrival_ms: float = 0.0) -> SchemeResult:
    """Run ``workload`` while populating one ad-hoc index under the
    given scheme ('full' | 'vap' | 'vbp' | 'vbp_decoupled' | 'none').

    Every scheme gets the SAME background construction bandwidth
    (``units_per_cycle`` tuple-touches per tuning cycle) so the
    comparison isolates *when the index becomes usable*:

    * FULL accrues the budget silently; the index flips usable only
      once the whole build is paid for (online indexing).
    * VAP applies the budget page-by-page; the hybrid scan exploits the
      indexed prefix immediately.
    * VBP populates the queried sub-domain synchronously inside the
      triggering query (latency spike); background budget unused.
    * VBP-decoupled queues sub-domains and populates them with the
      background budget (the spike-free variant of Section VI-B).
    """
    db = Database(dict(db_src.tables), time_per_unit_ms=time_per_unit_ms)
    table = workload.items[0][1].table
    t_tbl = db.tables[table]
    res = SchemeResult(scheme)
    bi = None
    if scheme in ("full", "vap"):
        bi = db.create_index(IndexDescriptor(table, tuple(key_attrs)),
                             scheme="full" if scheme == "full" else "vap")
    elif scheme in ("vbp", "vbp_decoupled"):
        bi = db.create_index(IndexDescriptor(table, tuple(key_attrs)),
                             scheme="vbp")
    next_cycle = tuning_interval_ms
    pending: List = []             # decoupled-VBP population queue
    full_units_accrued = 0.0
    full_units_needed = float(int(t_tbl.n_rows))
    page_size = t_tbl.page_size
    idle_ms_accum = 0.0
    # the tuner converts idle time into extra build budget (Section V:
    # "characterizes the tuner's ability to leverage idle resources");
    # half a core's worth of tuple-touches per idle millisecond.
    idle_units_per_ms = 0.5 / time_per_unit_ms

    t0 = time.perf_counter()
    for _, q in workload:
        # background tuning cycles: base budget + idle-time boost
        while db.clock_ms >= next_cycle:
            budget = units_per_cycle + idle_ms_accum * idle_units_per_ms
            idle_ms_accum = 0.0
            if scheme == "vap" and bi.building:
                pages = max(int(budget) // page_size, 1)
                db.vap_build_step(bi, pages)
            elif scheme == "full" and bi.building:
                full_units_accrued += budget
                if full_units_accrued >= full_units_needed:
                    db.vap_build_step(bi, t_tbl.n_pages)  # flip complete
            elif scheme == "vbp_decoupled" and pending:
                probe = pending[0]
                db.vbp_populate(bi, probe, max_add=max(int(budget), 1))
                lo, hi = db.planner.vbp_host_bounds(bi, probe)
                if bi.cov_union.covers(lo, hi):
                    pending.pop(0)
            next_cycle += tuning_interval_ms

        stats = db.execute(q)
        lat = stats.latency_ms
        if scheme == "vbp" and q.kind == "scan" and not stats.used_index:
            # immediate value-based population: charged to this query
            work = db.vbp_populate(bi, q, max_add=t_tbl.capacity)
            lat += work * time_per_unit_ms
            db.clock_ms += work * time_per_unit_ms
        elif scheme == "vbp_decoupled" and q.kind == "scan" \
                and not stats.used_index:
            lo, hi = db.planner.vbp_host_bounds(bi, q)
            if not bi.cov_union.covers(lo, hi) and q not in pending:
                pending.append(q)
        res.latencies_ms.append(lat)
        res.cumulative_ms += lat
        res.built_fraction.append(
            bi.built_fraction(db.tables[table]) if bi else 0.0)
        if arrival_ms > 0.0 and lat < arrival_ms:
            # open-loop client: the next request arrives on a fixed
            # cadence; the gap is idle time the background tuner rides.
            db.clock_ms += arrival_ms - lat
            idle_ms_accum += arrival_ms - lat
    res.wall_s = time.perf_counter() - t0
    return res


# Every emit() is also recorded here so benchmark drivers can dump a
# machine-readable artifact (benchmarks/run.py --json; the nightly CI
# job uploads it to build a perf trajectory across runs).
RECORDS: List[Dict[str, object]] = []


def reset_records() -> None:
    RECORDS.clear()


def emit(name: str, us_per_call: float, derived: str,
         speedup: float | None = None,
         direction: str = "lower") -> None:
    """The run.py CSV contract: name,us_per_call,derived.

    ``us_per_call`` is the benchmark's central (median-style) latency
    metric; ``speedup`` optionally records the benchmark's headline
    ratio vs its own baseline.  Both land in the machine-readable
    record (``--json``) that the nightly trajectory gate compares
    across runs (benchmarks/trajectory.py).

    ``direction`` declares how the gate should read ``us_per_call``:
    "lower" (the default: a latency, lower is better), "higher" (a
    throughput/speedup ratio, higher is better) or "info" (a count or
    environment fact the gate must not judge).  Only non-default
    directions are written into the record."""
    if direction not in ("lower", "higher", "info"):
        raise ValueError(f"emit direction: {direction!r}")
    rec = {"name": name, "us_per_call": round(us_per_call, 3),
           "median_ms": round(us_per_call / 1e3, 6), "derived": derived}
    if speedup is not None:
        rec["speedup"] = round(speedup, 4)
    if direction != "lower":
        rec["direction"] = direction
    RECORDS.append(rec)
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()
