"""Microbenchmark: fused single-dispatch sharded scans vs the
per-shard loop fan-out.

The per-shard loop traces one vmapped scan body PER SHARD into every
burst program, so trace size and compile time grow ~S x with the
shard count (and with them the cost of every fresh burst shape);
the stacked forms vmap the identical body over a cached padded shard
pytree, so the program is the same size for any S (core/engine.py).
Both strategies are bit-identical (asserted here and in
tests/test_fused_shard_scan.py).

The headline measures *read-burst throughput on shape-shifting
bursts*: real burst sizes vary statement to statement, and every
fresh (batch, aggregate) shape pays a full trace+compile before its
dispatch -- on CPU that is hundreds of milliseconds against a
sub-millisecond steady dispatch, so burst throughput under shifting
shapes is exactly the ~S x trace tax the fused layout removes.  At
S=4 the fused hybrid burst sustains >= 2-3x the loop fan-out's
throughput; steady-state (pre-compiled shape) dispatch timings are
emitted as info records (they are a wash on one CPU core -- XLA runs
the loop's per-shard ops in parallel -- and become the multi-device
win via the pmap/TPU paths).

    PYTHONPATH=src python -m benchmarks.fused_shard_scan
"""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.api import make_tuner_db
from repro.core import engine as eng
from repro.core.index import make_sharded_index, sharded_build_pages_vap
from repro.core.table import shard_table

HEADLINE_S = 4


def _bounds(n_queries, seed):
    rng = np.random.default_rng(seed)
    los = rng.integers(1, 5 * 10**5, size=(n_queries, 1)).astype(np.int32)
    his = los + 10_000
    tss = np.full((n_queries,), 5, np.int32)
    return jnp.asarray(los), jnp.asarray(his), jnp.asarray(tss)


def _steady_us(fn, inner=5, rounds=5):
    """Min-of-rounds steady-state time per call (compiled shape)."""
    fn()
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        best = min(best, (time.perf_counter() - t0) / inner)
    return best * 1e6


def _assert_bit_identical(st, ix, los, his, tss, S):
    pairs = (
        (eng.sharded_batched_full_table_scan_loop(st, (1,), los, his, tss, 2),
         eng.sharded_batched_full_table_scan(st, (1,), los, his, tss, 2)),
        (eng.sharded_batched_hybrid_scan_loop(
            st, ix, (1,), (1,), los, his, tss, 2),
         eng.sharded_batched_hybrid_scan(
            st, ix, (1,), (1,), los, his, tss, 2)),
    )
    for a, b in pairs:
        for f, x, y in zip(a._fields, a, b):
            assert (np.asarray(x) == np.asarray(y)).all(), \
                f"fused S={S} diverges from loop on {f}"


def run(n_queries: int = 16, n_rows: int = 4_096, page_size: int = 128,
        shard_counts=(1, 4, 8), bursts: int = 3, quiet: bool = False):
    src = make_tuner_db(n_rows=n_rows, page_size=page_size)
    t = src.tables["narrow"]
    headline = None

    for S in shard_counts:
        st = shard_table(t, S)
        ix = make_sharded_index(st)
        ix = sharded_build_pages_vap(ix, st, (1,), t.n_pages // 2)

        los, his, tss = _bounds(n_queries, seed=17)
        _assert_bit_identical(st, ix, los, his, tss, S)

        # Shape-shifting hybrid bursts: every burst is a fresh
        # (batch size, aggregate attr) combination, so each strategy
        # pays its own trace+compile per burst -- the dominant cost of
        # serving bursts whose shapes shift.
        shapes = [(n_queries - 1 - k, 3 + k) for k in range(bursts)]

        def run_bursts(fused: bool) -> float:
            total_q = 0
            t0 = time.perf_counter()
            for k, (B, agg) in enumerate(shapes):
                lo_k, hi_k, ts_k = _bounds(B, seed=100 * S + k)
                if fused:
                    r = eng.sharded_batched_hybrid_scan(
                        st, ix, (1,), (1,), lo_k, hi_k, ts_k, agg)
                else:
                    r = eng.sharded_batched_hybrid_scan_loop(
                        st, ix, (1,), (1,), lo_k, hi_k, ts_k, agg)
                r.agg_sum.block_until_ready()
                total_q += B
            return (time.perf_counter() - t0) / total_q * 1e6

        us_loop = run_bursts(fused=False)
        us_fused = run_bursts(fused=True)
        speedup = us_loop / us_fused
        is_headline = S == HEADLINE_S
        if is_headline:
            headline = speedup
        # Absolute burst latency is compile-dominated (machine
        # sensitive) -> info; the within-run RATIO is the gated
        # headline record below.
        emit(f"fused_shard_scan.shifting_burst.shards{S}", us_fused,
             f"{bursts} fresh-shape hybrid bursts, fused single "
             f"dispatch, {speedup:.2f}x vs per-shard loop",
             speedup=speedup if is_headline else None, direction="info")
        emit(f"fused_shard_scan.shifting_burst.shards{S}.loop", us_loop,
             "per-shard loop fan-out baseline", direction="info")
        if not quiet:
            print(f"# shifting bursts S={S}: fused {us_fused:.0f}us/q vs "
                  f"loop {us_loop:.0f}us/q ({speedup:.2f}x)")

        # Steady state (compiled shape): a wash on one CPU core, the
        # multi-device win rides the pmap/TPU paths.  Info records.
        steady_loop = _steady_us(
            lambda: eng.sharded_batched_hybrid_scan_loop(
                st, ix, (1,), (1,), los, his, tss, 2
            ).agg_sum.block_until_ready()) / n_queries
        steady_fused = _steady_us(
            lambda: eng.sharded_batched_hybrid_scan(
                st, ix, (1,), (1,), los, his, tss, 2
            ).agg_sum.block_until_ready()) / n_queries
        emit(f"fused_shard_scan.steady.shards{S}", steady_fused,
             f"compiled-shape hybrid burst, "
             f"{steady_loop / steady_fused:.2f}x vs loop "
             f"({steady_loop:.1f}us/q)", direction="info")

    if headline is not None:
        emit("fused_shard_scan.headline_speedup_s4", headline,
             f"shape-shifting read-burst throughput, fused vs "
             f"per-shard loop at S={HEADLINE_S}",
             speedup=headline, direction="higher")
    return headline


if __name__ == "__main__":
    run()
