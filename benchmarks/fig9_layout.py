"""Figure 9: index tuning in tandem with storage-layout tuning.

Read-only moderate-complexity scans over the WIDE table (p = 200
attributes) at 1% and 10% projectivity/selectivity, under four modes:
Disabled / Index only / Layout only / Both.  Paper's claims: at high
proj/sel the tuners give 1.9x (index), 1.5x (layout), 2.7x (both); at
1%/1% the combination reaches 7.8x.
"""
from __future__ import annotations


from benchmarks.common import emit
from repro.api import (Database, PredictiveTuner, QueryGen, RunConfig,
                       TunerConfig, affinity_workload, make_tuner_db,
                       run_workload)
from repro.core.baselines import DisabledTuner
from repro.core.layout import LayoutTuner


class LayoutOnlyTuner(DisabledTuner):
    """Wraps the storage-layout tuner in the tuner interface."""

    name = "layout"

    def __init__(self, db, pages_per_cycle: int = 64):
        super().__init__(db)
        self.lt = LayoutTuner(pages_per_cycle=pages_per_cycle,
                              page_size=next(iter(db.tables.values())).page_size)

    def tuning_cycle(self, idle: bool = False) -> float:
        work_ms = 0.0
        for name, state in self.db.layouts.items():
            recs = [r for r in self.db.monitor.records if r.table == name]
            accessed = [tuple(sorted(set(r.accessed_attrs) or
                                     set(r.pred_attrs))) for r in recs]
            self.lt.retarget(state, accessed)
            work_ms += self.lt.cycle(state)
        return work_ms / max(self.db.time_per_unit_ms, 1e-12) * 1e-3


class BothTuner(LayoutOnlyTuner):
    name = "both"

    def __init__(self, db, tcfg):
        super().__init__(db)
        self.index_tuner = PredictiveTuner(db, tcfg)

    def tuning_cycle(self, idle: bool = False) -> float:
        return (super().tuning_cycle(idle)
                + self.index_tuner.tuning_cycle(idle))


def run(n_rows: int = 6_000, total: int = 500, quiet: bool = False):
    results = {}
    for sel, proj, tag in [(0.10, 0.10, "high"), (0.01, 0.01, "low")]:
        db_src = make_tuner_db(n_rows=n_rows, page_size=128,
                               include_wide=True, narrow_attrs=20)
        gen = QueryGen(db_src, table="wide", selectivity=sel,
                       projectivity=proj)
        wl = affinity_workload(gen, total=total, phase_len=total,
                               n_subdomains=6, template="mod_s")
        tcfg = TunerConfig(storage_budget_bytes=50e6, pages_per_cycle=16,
                           max_build_pages_per_cycle=64,
                           candidate_min_count=2)
        row = {}
        for name, make in [
            ("disabled", lambda d: DisabledTuner(d)),
            ("index", lambda d: PredictiveTuner(d, tcfg)),
            ("layout", lambda d: LayoutOnlyTuner(d)),
            ("both", lambda d: BothTuner(d, tcfg)),
        ]:
            db = Database(dict(db_src.tables))
            res = run_workload(db, make(db), wl,
                               RunConfig(tuning_interval_ms=25.0))
            row[name] = res
            if not quiet:
                print(f"   {tag} sel/proj {name:9s}", res.summary())
        results[tag] = row
        base = row["disabled"].cumulative_ms
        emit(f"fig9.{tag}_selproj",
             row["both"].cumulative_ms * 1e3 / total,
             f"index={base / row['index'].cumulative_ms:.2f}x "
             f"layout={base / row['layout'].cumulative_ms:.2f}x "
             f"both={base / row['both'].cumulative_ms:.2f}x "
             f"(paper high: 1.9/1.5/2.7, low: -/-/7.8)")
    return results


if __name__ == "__main__":
    run()
