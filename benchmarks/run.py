"""Benchmark driver: one function per paper figure/table + the kernel
microbenchmark + the roofline summary.  Prints ``name,us_per_call,
derived`` CSV lines (the ``emit`` contract in common.py).

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def bench_kernels():
    """Pallas filter_agg vs pure-jnp reference (interpret mode on this
    container -- the comparison point is correctness + call overhead;
    TPU timings come from real deployments)."""
    from benchmarks.common import emit
    from repro.bench_db.schema import make_tuner_db
    from repro.kernels import ops
    from repro.kernels.ref import filter_agg_ref

    db = make_tuner_db(n_rows=40_000, page_size=256)
    t = db.tables["narrow"]
    lo, hi = db.quantile_bounds("narrow", 0.01, 0.3)

    def timed(fn, n=5):
        fn()  # compile
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        return (time.perf_counter() - t0) / n * 1e6

    us_ref = timed(lambda: filter_agg_ref(
        t.data[:, :, 1], t.data[:, :, 1], t.data[:, :, 2], t.begin_ts,
        t.end_ts, lo, hi, ops.I32_MIN, ops.I32_MAX, 0)[0].block_until_ready())
    us_pal = timed(lambda: ops.scan_table(
        t, (1,), (lo,), (hi,), ts=0, agg_attr=2)[0].block_until_ready())
    emit("kernel.filter_agg_ref_jnp", us_ref, "pure-jnp oracle")
    emit("kernel.filter_agg_pallas_interpret", us_pal,
         "pl.pallas_call interpret=True (CPU correctness mode)")


def bench_roofline():
    from benchmarks.common import emit
    from benchmarks import roofline
    rows = []
    try:
        rows = roofline.table(out=open("/dev/null", "w"))
    except Exception:
        pass
    if not rows:
        emit("roofline.table", 0.0, "no dryrun artifacts yet "
             "(run python -m repro.launch.dryrun --all)")
        return
    worst = min(rows, key=lambda rt: rt[1]["roofline_fraction"])
    collb = max(rows, key=lambda rt: rt[1]["collective_s"])
    for rec, t in rows:
        emit(f"roofline.{rec['arch']}.{rec['shape']}",
             t["dominant_s"] * 1e6,
             f"dom={t['dominant']} roofline={100*t['roofline_fraction']:.1f}% "
             f"useful={t['useful_ratio']:.2f} peak={t['peak_gib']:.1f}GiB")
    emit("roofline.worst_cell", worst[1]["dominant_s"] * 1e6,
         f"{worst[0]['arch']}/{worst[0]['shape']} "
         f"{100*worst[1]['roofline_fraction']:.1f}%")
    emit("roofline.most_collective_bound", collb[1]["collective_s"] * 1e6,
         f"{collb[0]['arch']}/{collb[0]['shape']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI mode)")
    ap.add_argument("--all", action="store_true",
                    help="run every registered benchmark (the default; "
                         "spelled out for scripts)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="print the registered benchmark names and exit")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted records as JSON "
                         "(the nightly-CI perf artifact)")
    args = ap.parse_args()

    from benchmarks import (async_tuning, batched_scan, crack_on_scan,
                            fault_recovery, fig2_schemes,
                            fig6_decision_logic, fig7_holistic,
                            fig8_affinity, fig9_layout,
                            fig10_adaptability, fused_shard_scan,
                            mesh_scan, replica_routing, serving_slo,
                            shard_tuning, sharded_scan)
    from benchmarks import common

    quick = args.quick
    jobs = [
        ("fig2", lambda: fig2_schemes.run(
            total=600 if quick else 1500, quiet=True)),
        ("fig6", lambda: fig6_decision_logic.run(
            total=1200 if quick else 3000,
            phase_len=150 if quick else 300, quiet=True)),
        ("fig7", lambda: fig7_holistic.run(
            seg_len=150 if quick else 400, quiet=True)),
        ("fig8", lambda: fig8_affinity.run(
            total=500 if quick else 1200, quiet=True)),
        ("fig9", lambda: fig9_layout.run(
            total=250 if quick else 500, quiet=True)),
        ("fig10", lambda: fig10_adaptability.run(
            total=600 if quick else 1500, quiet=True)),
        ("batched", lambda: batched_scan.run(
            n_queries=64 if quick else 128, quiet=True)),
        ("sharded", lambda: sharded_scan.run(
            n_queries=32 if quick else 64,
            n_rows=10_000 if quick else 20_000, quiet=True)),
        ("async", lambda: async_tuning.run(
            total=400 if quick else 1200, quiet=True)),
        ("shard_tuning", lambda: shard_tuning.run(
            total=240 if quick else 360,
            phase_len=120 if quick else 180, quiet=True)),
        ("crack_on_scan", lambda: crack_on_scan.run(
            total=160 if quick else 240,
            phase_len=55 if quick else 80, quiet=True)),
        ("fused_shard", lambda: fused_shard_scan.run(
            bursts=2 if quick else 3, quiet=True)),
        # burst size NOT reduced under --quick: the headline is burst
        # amortization of the mesh dispatch's fixed cost, which needs
        # the full burst to be meaningful (see mesh_scan docstring)
        ("mesh", lambda: mesh_scan.run(quiet=True)),
        ("serving_slo", lambda: serving_slo.run(
            total=400 if quick else 1200,
            phase_len=100 if quick else 150, quiet=True)),
        ("replica_routing", lambda: replica_routing.run(
            total=120 if quick else 240, quiet=True)),
        ("fault_recovery", lambda: fault_recovery.run(
            total=120 if quick else 240, quiet=True)),
        ("kernels", bench_kernels),
        ("roofline", bench_roofline),
    ]
    names = [name for name, _ in jobs]
    if args.list:
        print("\n".join(names))
        return
    if args.only is not None and args.only not in names:
        # A typo must not silently run *nothing* -- fail loudly with
        # the registry so scripts and CI notice.
        raise SystemExit(
            f"run.py: unknown benchmark {args.only!r}; "
            f"known benchmarks: {', '.join(names)}")

    common.reset_records()
    failures = []
    for name, fn in jobs:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)
        except Exception as e:
            failures.append(name)
            traceback.print_exc()
            print(f"{name}.FAILED,0.0,{e!r}")
    if args.json:
        import json
        import platform
        # Stable BENCH_<prnum>.json schema (benchmarks/trajectory.py
        # compares these run over run): bump "schema" only on
        # incompatible record changes.  Each record carries name, the
        # median-style latency (us_per_call / median_ms) and, where a
        # benchmark has a baseline, its headline speedup.
        payload = {
            "schema": 1,
            "created_unix_s": round(time.time(), 1),
            "argv": sys.argv[1:],
            "python": platform.python_version(),
            "failures": failures,
            "records": common.RECORDS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(common.RECORDS)} records to {args.json}",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
